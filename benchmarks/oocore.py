"""Out-of-core corpus store bench (DESIGN.md §9): streaming-build throughput
and store-backed query QPS across residency budget × block size, plus the
async-prefetch and sharded-serving columns.

The sweep writes the corpus to an on-disk block store, then for each
(budget fraction, block_docs) setting:

- **streaming build** (`build_from_store`) — docs/s with only tree pages +
  one batch + the budgeted block cache resident (the paper's "disk based
  implementations where space requirements exceed that of main memory");
- **store-backed queries** (`topk_search(tree, store_slice)`) — QPS with
  chunk fetches coming off disk through the dispatch-ahead pipeline, against
  the in-memory baseline on identical queries;
- **prefetch column** — the same queries with `prefetch` 1 and 2 (a
  `store.Prefetcher` reader thread moves the disk read off the dispatch
  path), plus one prefetched streaming build per block size;
- **sharded column** (`--mesh N`, needs N visible devices) — store-backed
  `topk_search_sharded` with per-shard block caches
  (`backend.shard_from_store`), reporting QPS and peak store residency;
- an **equivalence assertion** on every variant: answers must stay
  bit-identical to the in-memory path (the §9 contract; the full matrix
  lives in tests/test_store.py + tests/test_query_sharded.py).

Budgets are fractions of the decoded corpus size, so sub-1.0 settings really
do evict (`cache.evictions` lands in the JSON). Results → ``--json
BENCH_oocore.json`` (archived by the oocore + oocore-sharded CI jobs).

Run:  PYTHONPATH=src python benchmarks/oocore.py [--smoke] [--mesh N] \
          [--json BENCH_oocore.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp


def main(
    n_docs: int = 4000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    beam: int = 4,
    chunk: int = 256,
    block_sizes=(256, 1024),
    budget_fractions=(0.1, 0.5, 1.0),
    n_queries: int = 512,
    repeats: int = 3,
    seed: int = 0,
    store_dir: str | None = None,
    json_path: str | None = None,
    prefetch_depths=(1, 2),
    mesh_shards: int = 0,
):
    from repro.core import ktree as kt
    from repro.core.query import topk_search, topk_search_sharded
    from repro.core.store import open_store, save_store
    from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
    from repro.sparse.csr import csr_to_dense

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    nq = min(n_queries, n_docs)
    base_dir = store_dir or tempfile.mkdtemp(prefix="oocore_")

    rows, blob = [], {
        "n_docs": n_docs, "dim": x_all.shape[1], "k": k, "beam": beam,
        "chunk": chunk, "n_queries": nq,
        "build_docs_per_s": {}, "query_qps": {}, "cache": {},
        "prefetch_query_qps": {}, "prefetch_build_docs_per_s": {},
        "sharded": {},
    }

    # in-memory baselines: build once per nothing (independent of store shape)
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    tree_mem = kt.build(jnp.asarray(x_all), order=order, batch_size=256, key=key)
    mem_build_s = time.perf_counter() - t0
    rows.append(("oocore_build_inmemory", mem_build_s / n_docs * 1e6,
                 f"docs_per_s={n_docs/max(mem_build_s,1e-9):.0f}"))
    blob["build_docs_per_s"]["inmemory"] = n_docs / max(mem_build_s, 1e-9)

    x_q = jnp.asarray(x_all[:nq])
    topk_search(tree_mem, x_q, k=k, beam=beam, chunk=chunk)  # warm
    lat = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        d_mem, s_mem = topk_search(tree_mem, x_q, k=k, beam=beam, chunk=chunk)
        lat.append(time.perf_counter() - t0)
    mem_qps = nq / max(float(np.median(lat)), 1e-9)
    rows.append(("oocore_query_inmemory", np.median(lat) / nq * 1e6,
                 f"qps={mem_qps:.0f}"))
    blob["query_qps"]["inmemory"] = mem_qps

    for block_docs in block_sizes:
        path = os.path.join(base_dir, f"blk{block_docs}")
        t0 = time.perf_counter()
        save_store(path, x_all, block_docs=block_docs)
        t_write = time.perf_counter() - t0
        rows.append((f"oocore_store_write_blk{block_docs}",
                     t_write / n_docs * 1e6,
                     f"docs_per_s={n_docs/max(t_write,1e-9):.0f}"))
        probe = open_store(path)
        corpus_bytes = probe.nbytes

        for frac in budget_fractions:
            budget = max(int(corpus_bytes * frac), 1)
            tag = f"blk{block_docs}_budget{int(frac*100)}pct"

            # --- streaming build under this residency budget ----------------
            store = open_store(path, budget_bytes=budget)
            t0 = time.perf_counter()
            tree_st = kt.build_from_store(store, order=order, batch_size=256,
                                          key=key)
            t_build = time.perf_counter() - t0
            bs = store.cache.stats
            rows.append((
                f"oocore_build_{tag}", t_build / n_docs * 1e6,
                f"docs_per_s={n_docs/max(t_build,1e-9):.0f} "
                f"evictions={bs['evictions']} "
                f"resident={bs['resident_bytes']/1e6:.1f}MB",
            ))
            blob["build_docs_per_s"][tag] = n_docs / max(t_build, 1e-9)

            # --- store-backed queries under the same budget -----------------
            store = open_store(path, budget_bytes=budget)
            q_view = store.view(0, nq)
            topk_search(tree_mem, q_view, k=k, beam=beam, chunk=chunk)  # warm
            lat = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                d_st, s_st = topk_search(tree_mem, q_view, k=k, beam=beam,
                                         chunk=chunk)
                lat.append(time.perf_counter() - t0)
            qps = nq / max(float(np.median(lat)), 1e-9)
            qs = store.cache.stats
            # §9 contract: disk-backed answers == in-memory answers, bit for bit
            np.testing.assert_array_equal(d_mem, d_st)
            np.testing.assert_array_equal(s_mem, s_st)
            rows.append((
                f"oocore_query_{tag}", np.median(lat) / nq * 1e6,
                f"qps={qps:.0f} vs_inmemory={qps/max(mem_qps,1e-9):.2f}x "
                f"block_hit_rate={qs['hit_rate']:.2f} exact=yes",
            ))
            blob["query_qps"][tag] = qps
            blob["cache"][tag] = {
                "build": bs, "query": qs,
                "budget_bytes": budget, "corpus_bytes": corpus_bytes,
            }
            # the streaming tree must be the in-memory tree, bit for bit
            import dataclasses

            for f in dataclasses.fields(tree_mem):
                if f.metadata.get("static"):
                    continue
                np.testing.assert_array_equal(
                    np.asarray(getattr(tree_mem, f.name)),
                    np.asarray(getattr(tree_st, f.name)), err_msg=f.name,
                )

            # --- prefetch column: async reader thread ahead of the reads ----
            for depth in prefetch_depths:
                store = open_store(path, budget_bytes=budget)
                q_view = store.view(0, nq)
                lat = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    d_pf, s_pf = topk_search(tree_mem, q_view, k=k, beam=beam,
                                             chunk=chunk, prefetch=depth)
                    lat.append(time.perf_counter() - t0)
                pf_qps = nq / max(float(np.median(lat)), 1e-9)
                np.testing.assert_array_equal(d_mem, d_pf)
                np.testing.assert_array_equal(s_mem, s_pf)
                rows.append((
                    f"oocore_query_{tag}_pf{depth}",
                    np.median(lat) / nq * 1e6,
                    f"qps={pf_qps:.0f} vs_sync={pf_qps/max(qps,1e-9):.2f}x "
                    f"exact=yes",
                ))
                blob["prefetch_query_qps"][f"{tag}_pf{depth}"] = pf_qps

        # --- prefetched streaming build (one per block size) ----------------
        store = open_store(path, budget_bytes=budget)
        t0 = time.perf_counter()
        tree_pf = kt.build_from_store(store, order=order, batch_size=256,
                                      key=key, prefetch=2)
        t_build = time.perf_counter() - t0
        for f in dataclasses.fields(tree_mem):
            if f.metadata.get("static"):
                continue
            np.testing.assert_array_equal(
                np.asarray(getattr(tree_mem, f.name)),
                np.asarray(getattr(tree_pf, f.name)), err_msg=f.name,
            )
        rows.append((f"oocore_build_blk{block_docs}_pf2",
                     t_build / n_docs * 1e6,
                     f"docs_per_s={n_docs/max(t_build,1e-9):.0f} exact=yes"))
        blob["prefetch_build_docs_per_s"][f"blk{block_docs}_pf2"] = (
            n_docs / max(t_build, 1e-9))

        # --- sharded column: store-backed shard-parallel serving ------------
        if mesh_shards > 1:
            import jax as _jax

            from repro.core.backend import shard_from_store

            if len(_jax.devices()) < mesh_shards:
                rows.append((f"oocore_sharded_blk{block_docs}", 0.0,
                             f"skipped: {len(_jax.devices())} devices "
                             f"< {mesh_shards}"))
            else:
                mesh = _jax.make_mesh((mesh_shards,), ("data",))
                x_qd = np.asarray(x_q)
                d_shm, s_shm = topk_search_sharded(
                    mesh, tree_mem, x_qd, corpus=x_all, k=k, beam=beam,
                    chunk=chunk,
                )
                store = open_store(path, budget_bytes=budget)
                per_shard = max(budget // mesh_shards, 1)
                sshards = shard_from_store(mesh, store,
                                           budget_bytes=per_shard)
                topk_search_sharded(mesh, tree_mem, x_qd, corpus=sshards,
                                    k=k, beam=beam, chunk=chunk)  # warm
                lat = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    d_sh, s_sh = topk_search_sharded(
                        mesh, tree_mem, x_qd, corpus=sshards, k=k, beam=beam,
                        chunk=chunk,
                    )
                    lat.append(time.perf_counter() - t0)
                sh_qps = nq / max(float(np.median(lat)), 1e-9)
                # §9 sharded contract: disk-backed == in-memory sharded, bit
                # for bit, with residency bounded by the per-shard budgets
                np.testing.assert_array_equal(d_shm, d_sh)
                np.testing.assert_array_equal(s_shm, s_sh)
                peak = sshards.peak_resident_bytes
                rows.append((
                    f"oocore_sharded_blk{block_docs}",
                    np.median(lat) / nq * 1e6,
                    f"qps={sh_qps:.0f} shards={mesh_shards} "
                    f"peak_resident={peak/1e6:.2f}MB exact=yes",
                ))
                blob["sharded"][f"blk{block_docs}"] = {
                    "qps": sh_qps, "n_shards": mesh_shards,
                    "per_shard_budget_bytes": per_shard,
                    "peak_resident_bytes": peak,
                    "per_shard_cache": sshards.cache_stats,
                }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        rows.append(("oocore_bench_json", 0.0, f"wrote {json_path}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--blocks", type=int, nargs="+", default=[256, 1024])
    ap.add_argument("--budgets", type=float, nargs="+", default=[0.1, 0.5, 1.0])
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--store-dir", default="", help="keep stores here "
                    "(default: a fresh temp dir)")
    ap.add_argument("--json", default="", help="write BENCH_oocore.json here")
    ap.add_argument("--mesh", type=int, default=0, help="add the sharded "
                    "column: store-backed topk_search_sharded over N shards "
                    "(needs N visible devices, e.g. "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, tight budgets (forces real "
             "evictions), short sweep",
    )
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.culled, args.order = 600, 250, 10
        args.blocks, args.budgets = [64, 256], [0.05, 0.5]
        args.queries, args.repeats, args.chunk = 256, 2, 128
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        beam=args.beam, chunk=args.chunk, block_sizes=tuple(args.blocks),
        budget_fractions=tuple(args.budgets), n_queries=args.queries,
        repeats=args.repeats, store_dir=args.store_dir or None,
        json_path=args.json or None, mesh_shards=args.mesh,
    ):
        print(f"{name},{us:.1f},{extra}")
