"""Random Indexing bench: recall@k vs projection dim (DESIGN.md §5.1).

The Random Indexing K-tree (PAPERS.md, arxiv 1001.0833) routes in an rp_dim-
dimensional seeded random projection and exact-rescores the leaf candidate
pool from the original rows, so recall@k is governed entirely by *routing*
quality — it must grow with rp_dim (Johnson–Lindenstrauss: higher dims
preserve more of the distance ordering) and reach the exact path at the
identity-scale anchor rp_dim = d (kind="identity"), which reproduces the
plain dense tree bit-for-bit. The sweep pins both trends, plus build time
and per-query latency per dim.

Results land in ``BENCH_ri.json`` (``--json``) so CI archives the recall
trajectory per commit.

Run:  PYTHONPATH=src python benchmarks/ri_recall.py [--smoke] \
          [--json BENCH_ri.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp


def main(
    n_docs: int = 3000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    rp_dims=(32, 64, 128, 256),
    beam: int = 4,
    n_queries: int = 256,
    seed: int = 0,
    json_path: str | None = None,
):
    """Run the rp_dim sweep; returns ``(name, us_per_call, derived)`` rows."""
    from repro.core import ktree as kt
    from repro.core.backend import (
        RandomProjBackend, make_backend, make_projection,
    )
    from repro.core.query import brute_force_topk, recall_at_k, topk_search
    from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
    from repro.sparse.csr import csr_to_dense

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    d = x_all.shape[1]
    base = make_backend(m, "sparse")
    nq = min(n_queries, n_docs)
    x_q = x_all[:nq]
    true_k = brute_force_topk(x_q, x_all, k)

    # exact-path reference: plain dense routing, no projection
    dense_tree = kt.build(make_backend(m, "dense"), order=order,
                          key=jax.random.PRNGKey(seed))
    docs_exact, _ = topk_search(dense_tree, jnp.asarray(x_q), k=k, beam=beam)
    recall_exact = recall_at_k(docs_exact, true_k)
    rows = [(
        "ri_exact_path", 0.0,
        f"docs={n_docs} d={d} order={order} recall@{k}={recall_exact:.3f}",
    )]
    blob = {
        "n_docs": n_docs, "d": d, "order": order, "k": k, "beam": beam,
        "recall_exact": recall_exact, "dims": {},
    }

    # identity-scale anchor + the rp_dim sweep; dims > d carry no extra
    # information on this corpus and are skipped with a note (no silent caps)
    sweep = [("identity", d)] + [("gaussian", rd) for rd in rp_dims if rd < d]
    for rd in rp_dims:
        if rd >= d:
            rows.append((f"ri_dim{rd}_skipped", 0.0,
                         f"rp_dim={rd} >= corpus d={d}; identity anchor "
                         "covers the exact-scale point"))
    prev = -1.0
    for kind, rd in sweep:
        proj = make_projection(d, rd, seed=seed, kind=kind)
        rpb = RandomProjBackend.wrap(base, proj)
        t0 = time.perf_counter()
        tree = kt.build(rpb, order=order, key=jax.random.PRNGKey(seed))
        t_build = time.perf_counter() - t0
        topk_search(tree, x_q, k=k, beam=beam, rp=rpb)  # warm the jit cache
        t0 = time.perf_counter()
        docs, _ = topk_search(tree, x_q, k=k, beam=beam, rp=rpb)
        dt = time.perf_counter() - t0
        rec = recall_at_k(docs, true_k)
        tag = f"ri_{kind}" if kind == "identity" else f"ri_dim{rd}"
        extra = (
            f"rp_dim={rd} recall@{k}={rec:.3f} qps={nq/max(dt,1e-9):.0f} "
            f"build_s={t_build:.2f}"
        )
        if kind == "identity":
            # the equivalence anchor: identity projection must reproduce the
            # exact path's answers, not just its recall
            ids_match = bool((np.asarray(docs) == np.asarray(docs_exact)).all())
            extra += f" ids_match_exact={ids_match}"
            if not ids_match:
                extra += " REGRESSION"
        else:
            trend = "+" if rec >= prev - 0.02 else "REGRESSION"
            prev = rec
            extra += f" trend={trend}"
        rows.append((tag, dt / nq * 1e6, extra))
        blob["dims"][str(rd)] = {
            "kind": kind, "recall": rec, "qps": nq / max(dt, 1e-9),
            "build_s": t_build,
        }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        rows.append(("ri_bench_json", 0.0, f"wrote {json_path}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=3000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--dims", type=int, nargs="+", default=[32, 64, 128, 256])
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--json", default="", help="write BENCH_ri.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, two projection dims",
    )
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.culled, args.order = 400, 200, 8
        args.dims, args.queries = [16, 64], 96
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        rp_dims=tuple(args.dims), beam=args.beam, n_queries=args.queries,
        json_path=args.json or None,
    ):
        print(f"{name},{us:.1f},{extra}")
