"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock of the kernel body is meaningless; what we CAN measure honestly:
- the XLA path that the kernel replaces (`pairwise_sqdist`+argmin) — CPU time,
- kernel-vs-oracle agreement across the production shapes,
- the analytic VMEM/roofline numbers for the TPU kernel (documented here).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kmeans import assign
from repro.kernels import ops, ref


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(1024, 256, 1000), (4096, 1024, 1000), (8192, 128, 8000)]
    jassign = jax.jit(lambda x, c: assign(x, c))
    for b, k, d in shapes:
        x = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(0, 1, (k, d)).astype(np.float32))
        us = timeit(jassign, x, c)
        flops = 2.0 * b * k * d
        rows.append((f"nn_assign_xla_b{b}_k{k}_d{d}", us, f"{flops/us/1e3:.1f}GFLOP/s"))
        # kernel agreement at this exact shape (interpret mode, 1 iter)
        idx_k, dist_k = ops.nn_assign(x[:256], c)
        idx_r, dist_r = ref.nn_assign_ref(x[:256], c)
        agree = float((np.asarray(idx_k) == np.asarray(idx_r)).mean())
        rows.append((f"nn_assign_pallas_agree_b256_k{k}_d{d}", 0.0, f"agree={agree:.4f}"))

    # ELL sparse path vs dense at a document-like sparsity
    b, d, k, nnz = 2048, 8000, 256, 96
    vals = rng.normal(0, 1, (b, nnz)).astype(np.float32)
    cols = rng.integers(0, d, (b, nnz)).astype(np.int32)
    c = rng.normal(0, 1, (k, d)).astype(np.float32)
    from repro.sparse.ell import ell_dot_dense, Ell
    e = Ell(jnp.asarray(vals), jnp.asarray(cols), d)
    ct = jnp.asarray(c.T)
    f_sp = jax.jit(lambda: ell_dot_dense(e, ct))
    us_sp = timeit(f_sp)
    x_dense = np.zeros((b, d), np.float32)
    np.put_along_axis(x_dense, cols, vals, axis=1)
    xd = jnp.asarray(x_dense)
    cj = jnp.asarray(c)
    f_de = jax.jit(lambda: xd @ cj.T)
    us_de = timeit(f_de)
    rows.append(("ell_scores_sparse_path", us_sp, f"dense={us_de:.0f}us ratio={us_sp/us_de:.2f}"))
    return rows


if __name__ == "__main__":
    for name, us, extra in main():
        print(f"{name},{us:.1f},{extra}")
