"""Paper §1's storage & speed observation, reproduced:

1. storage — the INEX matrix culled to 8000 terms: dense f32 needs 3.4 GB,
   sparse (2-byte index + 4-byte weight) needs ~58.5 MB. We recompute both
   numbers from the corpus spec and from a scaled generated corpus.
2. speed — NN search against *dense upper-tree centres*: scoring sparse docs
   (take+segment_sum CSR path) vs dense docs (matmul). The paper's point:
   near the root everything is dense, so the dense path wins on systolic/BLAS
   hardware while sparse wins on storage.
3. backends end-to-end — the same prepared corpus through ``ktree.build``
   under both vector backends (dense vs ELL sparse, medoid mode): build
   time, assignment purity, and the corpus bytes each backend holds
   resident. One entry point, two representations.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
from repro.sparse.csr import csr_matmat, csr_to_dense


def backend_compare(n_docs: int = 1500, culled: int = 600, order: int = 16, seed: int = 0):
    """Build the medoid K-tree over one TF-IDF corpus with both backends."""
    from repro.core import ktree as kt
    from repro.core.backend import make_backend
    from repro.core.metrics import micro_purity

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, labels = prepared_corpus(spec, seed=seed)
    rows = []
    for name, be in [
        ("dense", make_backend(m, "dense")),
        ("sparse", make_backend(m, "sparse")),
    ]:
        t0 = time.perf_counter()
        tree = kt.build(be, order=order, medoid=True, key=jax.random.PRNGKey(seed))
        jax.block_until_ready(tree.centers)
        dt = time.perf_counter() - t0
        kt.check_invariants(tree, n_docs=n_docs)
        assign, nc = kt.extract_assignment(tree, n_docs)
        p = float(micro_purity(
            jnp.asarray(assign), jnp.asarray(labels), nc, spec.n_labels
        ))
        if name == "dense":
            corpus_mb = be.x.size * be.x.dtype.itemsize / 1e6
        else:
            corpus_mb = (
                be.values.size * 4 + be.cols.size * 4
                + be.csr_data.size * 4 + be.csr_indices.size * 4
            ) / 1e6
        rows.append((
            f"ktree_build_{name}_backend",
            dt * 1e6,
            f"docs={n_docs} order={order} clusters={nc} "
            f"purity={p:.3f} corpus={corpus_mb:.1f}MB",
        ))
    return rows


def main(n_docs: int = 4000, culled: int = 2000):
    rows = []
    # --- storage accounting at FULL paper scale (exact paper arithmetic)
    full = INEX_LIKE
    dense_gb = full.n_docs * 8000 * 4 / 1e9
    nnz = 10_229_913            # paper's number for the culled INEX matrix
    sparse_mb = nnz * (2 + 4) / 1e6
    rows.append(("storage_dense_full_gb", 0.0, f"{dense_gb:.2f}GB(paper:3.4GB)"))
    rows.append(("storage_sparse_full_mb", 0.0, f"{sparse_mb:.2f}MB(paper:58.54MB)"))

    # --- generated corpus, scaled
    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=0)
    gen_dense_mb = m.n_rows * m.n_cols * 4 / 1e6
    gen_sparse_mb = m.nnz * 6 / 1e6
    rows.append(("storage_ratio_generated", 0.0,
                 f"dense={gen_dense_mb:.0f}MB sparse={gen_sparse_mb:.1f}MB "
                 f"x{gen_dense_mb/gen_sparse_mb:.0f}"))

    # --- speed: sparse-docs vs dense-docs against k dense centres
    k = 256
    rng = np.random.default_rng(0)
    centers_t = jnp.asarray(rng.normal(0, 1, (m.n_cols, k)).astype(np.float32))
    x_dense = jnp.asarray(np.asarray(csr_to_dense(m)))

    f_sparse = jax.jit(lambda ct: csr_matmat(m, ct))
    f_dense = jax.jit(lambda xd, ct: xd @ ct)
    for f, args, name in [
        (f_sparse, (centers_t,), "root_scores_sparse_docs"),
        (f_dense, (x_dense, centers_t), "root_scores_dense_docs"),
    ]:
        jax.block_until_ready(f(*args))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(*args))
        rows.append((name, (time.perf_counter() - t0) / 5 * 1e6, f"k={k}"))

    # --- the two K-tree vector backends end-to-end (tentpole path)
    rows.extend(backend_compare(
        n_docs=min(n_docs, 1500), culled=min(culled, 600), order=16
    ))
    return rows


if __name__ == "__main__":
    for name, us, extra in main():
        print(f"{name},{us:.1f},{extra}")
